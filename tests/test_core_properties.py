"""Hypothesis property tests on the FedFog core invariants (Eqs. 1-12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (
    ClientTelemetry,
    ColdStartConfig,
    EnergyModelConfig,
    Thresholds,
    decay_energy_threshold,
    epsilon,
    fedavg_stacked,
    fedavg_weights,
    health_score,
    kl_divergence,
    median_aggregate,
    normalize_histogram,
    required_sigma,
    select_clients,
    threshold_mask,
    topk_mask,
    trimmed_mean_aggregate,
    update_container_cache,
    utility_ranking,
    utility_score,
)
from repro.fl.compression import compress_int8, compress_topk

SETTINGS = settings(max_examples=30, deadline=None)

unit_floats = st.floats(0.0, 1.0, allow_nan=False, width=32, allow_subnormal=False)


def unit_arrays(n=st.integers(2, 12)):
    return n.flatmap(
        lambda k: hnp.arrays(
            np.float32, (k,), elements=unit_floats
        )
    )


def weight3():
    return (
        hnp.arrays(np.float32, (3,), elements=st.floats(0.015625, 1.0, width=32, allow_subnormal=False))
        .map(lambda a: a / a.sum())
    )


# --------------------------------------------------------------------- #
# Eq. 1 — health
# --------------------------------------------------------------------- #
@SETTINGS
@given(unit_arrays(), weight3())
def test_health_is_convex_combination(vals, alpha):
    tel = ClientTelemetry(
        cpu=jnp.asarray(vals), mem=jnp.asarray(vals), batt=jnp.asarray(vals),
        energy=jnp.asarray(vals),
    )
    h = np.asarray(health_score(tel, jnp.asarray(alpha)))
    assert (h >= -1e-5).all() and (h <= 1 + 1e-5).all()
    np.testing.assert_allclose(h, vals, atol=1e-5)  # equal inputs -> identity


@SETTINGS
@given(st.integers(2, 10), weight3(), st.data())
def test_health_monotone_in_cpu(n, alpha, data):
    base = data.draw(hnp.arrays(np.float32, (n,), elements=unit_floats))
    cpu_lo = data.draw(hnp.arrays(np.float32, (n,), elements=unit_floats))
    cpu_hi = np.minimum(cpu_lo + 0.1, 1.0).astype(np.float32)
    mk = lambda cpu: ClientTelemetry(
        cpu=jnp.asarray(cpu), mem=jnp.asarray(base), batt=jnp.asarray(base),
        energy=jnp.asarray(base),
    )
    a = jnp.asarray(alpha)
    h_lo = np.asarray(health_score(mk(cpu_lo), a))
    h_hi = np.asarray(health_score(mk(cpu_hi), a))
    assert (h_hi >= h_lo - 1e-6).all()


# --------------------------------------------------------------------- #
# Eq. 2 — KL drift
# --------------------------------------------------------------------- #
@SETTINGS
@given(
    hnp.arrays(np.float32, (6,), elements=st.floats(0.015625, 10.0, width=32, allow_subnormal=False)),
    hnp.arrays(np.float32, (6,), elements=st.floats(0.015625, 10.0, width=32, allow_subnormal=False)),
)
def test_kl_nonnegative_and_zero_iff_equal(p_raw, q_raw):
    p = normalize_histogram(jnp.asarray(p_raw))
    q = normalize_histogram(jnp.asarray(q_raw))
    kl = float(kl_divergence(p, q))
    assert kl >= -1e-6
    assert float(kl_divergence(p, p)) < 1e-6


# --------------------------------------------------------------------- #
# Eq. 3 / Eq. 7 — selection & utility
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.data())
def test_selection_monotone_in_thresholds(data):
    n = data.draw(st.integers(3, 16))
    h = data.draw(hnp.arrays(np.float32, (n,), elements=unit_floats))
    e = data.draw(hnp.arrays(np.float32, (n,), elements=unit_floats))
    d = data.draw(hnp.arrays(np.float32, (n,), elements=unit_floats))
    th_lo = data.draw(st.floats(0.0, 0.5, width=32, allow_subnormal=False))
    th_hi = th_lo + data.draw(st.floats(0.0, 0.5, width=32, allow_subnormal=False))
    mk = lambda t: threshold_mask(
        jnp.asarray(h), jnp.asarray(e), jnp.asarray(d),
        Thresholds(jnp.float32(t), jnp.float32(0.3), jnp.float32(0.5)),
    )
    lo, hi = np.asarray(mk(th_lo)), np.asarray(mk(th_hi))
    assert (hi <= lo).all()  # raising θ_h can only shrink C_t


@SETTINGS
@given(st.data())
def test_topk_respects_budget_and_eligibility(data):
    n = data.draw(st.integers(3, 20))
    k = data.draw(st.integers(1, n))
    u = data.draw(hnp.arrays(np.float32, (n,), elements=st.floats(-1, 1, width=32, allow_subnormal=False)))
    elig = data.draw(hnp.arrays(np.bool_, (n,)))
    mask = np.asarray(topk_mask(jnp.asarray(u), jnp.asarray(elig), k))
    assert mask.sum() <= k
    assert (mask <= elig).all()
    # kept clients have utility >= any dropped eligible client
    if mask.any() and (elig & ~mask).any():
        assert u[mask].min() >= u[elig & ~mask].max() - 1e-6


@SETTINGS
@given(st.data())
def test_utility_ranking_sorted(data):
    n = data.draw(st.integers(2, 16))
    u = data.draw(hnp.arrays(np.float32, (n,), elements=st.floats(-2, 2, width=32, allow_subnormal=False)))
    order = np.asarray(utility_ranking(jnp.asarray(u)))
    sorted_u = u[order]
    assert (np.diff(sorted_u) <= 1e-6).all()


# --------------------------------------------------------------------- #
# Eq. 6 — FedAvg
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.data())
def test_fedavg_convex_hull_and_weights(data):
    n = data.draw(st.integers(2, 8))
    d = data.draw(st.integers(1, 5))
    upd = data.draw(
        hnp.arrays(np.float32, (n, d), elements=st.floats(-5, 5, width=32, allow_subnormal=False))
    )
    sizes = data.draw(
        hnp.arrays(np.float32, (n,), elements=st.floats(1, 100, width=32, allow_subnormal=False))
    )
    mask = data.draw(hnp.arrays(np.bool_, (n,)))
    if not mask.any():
        mask[0] = True
    w = np.asarray(fedavg_weights(jnp.asarray(mask), jnp.asarray(sizes)))
    assert abs(w.sum() - 1.0) < 1e-4
    assert (w[~mask] == 0).all()
    agg = np.asarray(
        fedavg_stacked({"x": jnp.asarray(upd)}, jnp.asarray(mask), jnp.asarray(sizes))["x"]
    )
    sel = upd[mask]
    assert (agg <= sel.max(0) + 1e-4).all()
    assert (agg >= sel.min(0) - 1e-4).all()


@SETTINGS
@given(st.data())
def test_masked_clients_cannot_affect_fedavg(data):
    n, d = 5, 3
    upd = data.draw(
        hnp.arrays(np.float32, (n, d), elements=st.floats(-5, 5, width=32, allow_subnormal=False))
    )
    sizes = np.ones(n, np.float32)
    mask = np.array([True, True, False, True, False])
    poisoned = upd.copy()
    poisoned[~mask] = 1e6  # arbitrary garbage on masked clients
    a1 = np.asarray(fedavg_stacked({"x": jnp.asarray(upd)}, jnp.asarray(mask), jnp.asarray(sizes))["x"])
    a2 = np.asarray(fedavg_stacked({"x": jnp.asarray(poisoned)}, jnp.asarray(mask), jnp.asarray(sizes))["x"])
    np.testing.assert_allclose(a1, a2, atol=1e-4)


@SETTINGS
@given(st.data())
def test_robust_aggregators_bounded(data):
    n = data.draw(st.integers(3, 9))
    upd = data.draw(
        hnp.arrays(np.float32, (n, 4), elements=st.floats(-3, 3, width=32, allow_subnormal=False))
    )
    mask = np.ones(n, bool)
    med = np.asarray(median_aggregate({"x": jnp.asarray(upd)}, jnp.asarray(mask))["x"])
    tm = np.asarray(
        trimmed_mean_aggregate({"x": jnp.asarray(upd)}, jnp.asarray(mask))["x"]
    )
    for agg in (med, tm):
        assert (agg <= upd.max(0) + 1e-5).all()
        assert (agg >= upd.min(0) - 1e-5).all()


# --------------------------------------------------------------------- #
# Eq. 10 — energy budgeting
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.data())
def test_energy_decay_bounds_and_neutrality(data):
    n = data.draw(st.integers(2, 10))
    theta = data.draw(
        hnp.arrays(np.float32, (n,), elements=st.floats(0.125, 0.875, width=32, allow_subnormal=False))
    )
    cfg = EnergyModelConfig()
    # equal spend == average -> multiplicative factor exactly 1
    e = np.full(n, 3.0, np.float32)
    out = np.asarray(decay_energy_threshold(jnp.asarray(theta), jnp.asarray(e), cfg))
    np.testing.assert_allclose(out, np.clip(theta, cfg.theta_min, cfg.theta_max), atol=1e-5)
    # arbitrary spends stay within clip bounds
    e2 = data.draw(hnp.arrays(np.float32, (n,), elements=st.floats(0, 10, width=32, allow_subnormal=False)))
    out2 = np.asarray(decay_energy_threshold(jnp.asarray(theta), jnp.asarray(e2), cfg))
    assert (out2 >= cfg.theta_min - 1e-6).all() and (out2 <= cfg.theta_max + 1e-6).all()
    # above-average spender's threshold rises relative to below-average one
    e3 = np.zeros(n, np.float32)
    e3[0] = 10.0
    out3 = np.asarray(decay_energy_threshold(jnp.asarray(theta), jnp.asarray(e3), cfg))
    assert out3[0] >= np.clip(theta[0], cfg.theta_min, cfg.theta_max) - 1e-6
    assert (out3[1:] <= np.clip(theta[1:], cfg.theta_min, cfg.theta_max) + 1e-6).all()


# --------------------------------------------------------------------- #
# Eq. 4 — container cache
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.data())
def test_container_cache_semantics(data):
    n = data.draw(st.integers(2, 12))
    cfg = ColdStartConfig(keep_alive_rounds=data.draw(st.integers(1, 4)))
    warm = jnp.zeros((n,), bool)
    last = jnp.full((n,), -1, jnp.int32)
    mask = jnp.asarray(data.draw(hnp.arrays(np.bool_, (n,))))
    warm1, last1 = update_container_cache(warm, last, mask, jnp.int32(0), cfg)
    np.testing.assert_array_equal(np.asarray(warm1), np.asarray(mask))
    # idle for keep_alive rounds -> evicted
    w, l = warm1, last1
    for r in range(1, cfg.keep_alive_rounds + 1):
        w, l = update_container_cache(
            w, l, jnp.zeros((n,), bool), jnp.int32(r), cfg
        )
    assert not np.asarray(w).any()


def test_container_lru_capacity():
    cfg = ColdStartConfig(keep_alive_rounds=10, warm_capacity=2)
    warm = jnp.zeros((4,), bool)
    last = jnp.full((4,), -1, jnp.int32)
    for r, sel in enumerate([[0], [1], [2]]):
        mask = jnp.zeros((4,), bool).at[jnp.asarray(sel)].set(True)
        warm, last = update_container_cache(warm, last, mask, jnp.int32(r), cfg)
    w = np.asarray(warm)
    assert w.sum() <= 2
    assert w[2] and w[1] and not w[0]  # LRU evicted client 0


# --------------------------------------------------------------------- #
# Eq. 12 — DP accounting
# --------------------------------------------------------------------- #
@SETTINGS
@given(
    st.floats(0.1, 2.0), st.floats(0.1, 5.0), st.integers(1, 100),
)
def test_epsilon_monotonicity_and_inverse(sigma, s, n):
    eps = epsilon(sigma, s, n, 1e-5)
    assert eps > 0
    assert epsilon(sigma * 2, s, n, 1e-5) < eps  # more noise -> more private
    assert epsilon(sigma, s, n + 10, 1e-5) < eps  # amplification
    sig = required_sigma(eps, s, n, 1e-5)
    np.testing.assert_allclose(sig, sigma, rtol=1e-6)


# --------------------------------------------------------------------- #
# Compression
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.data())
def test_int8_error_bound(data):
    x = data.draw(
        hnp.arrays(np.float32, (3, 17), elements=st.floats(-4, 4, width=32, allow_subnormal=False))
    )
    out = np.asarray(compress_int8({"x": jnp.asarray(x)})["x"])
    scale = np.abs(x).max(axis=1, keepdims=True) / 127.0 + 1e-12
    assert (np.abs(out - x) <= scale * 0.5 + 1e-6).all()


def test_topk_keeps_largest():
    x = jnp.asarray(np.arange(20, dtype=np.float32)[None] - 10.0)
    out = np.asarray(compress_topk({"x": x}, 0.25)["x"])
    nz = np.nonzero(out[0])[0]
    assert len(nz) == 5
    kept = np.abs(np.asarray(x)[0])[nz]
    dropped = np.abs(np.asarray(x)[0][out[0] == 0])
    assert kept.min() >= dropped.max() - 1e-6
