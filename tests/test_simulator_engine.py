"""Scan-compiled engine, vmapped sweep, and shared DES cost model.

Covers the three contracts the simulation-stack refactor must hold:
  (a) ``run_scanned()`` reproduces the per-round loop for all policies;
  (b) sweeps are seed-deterministic and seed s of a sweep reproduces a
      standalone ``run_scanned()`` at seed s;
  (c) the shared ``RoundCostModel`` reproduces the seed repo's
      latency/energy formulas consumed by both engines.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.telemetry import TelemetryConfig, make_profiles
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim import (
    FaasSimConfig,
    RoundCostModel,
    round_energy_j,
    round_times_ms,
    run_sweep,
)

POLICIES = ("fedfog", "rcs", "fogfaas", "vanilla")


def _cfg(**kw) -> SimulatorConfig:
    base = dict(
        task="emnist", num_clients=8, rounds=4, top_k=4, hidden=(16,), seed=0
    )
    base.update(kw)
    return SimulatorConfig(**base)


# --------------------------------------------------------------------- #
# (a) scanned engine ≡ per-round loop
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", POLICIES)
def test_run_scanned_matches_loop(policy):
    cfg = _cfg(policy=policy)
    h_loop = FedFogSimulator(cfg).run()
    h_scan = FedFogSimulator(cfg).run_scanned()
    assert set(h_loop) == set(h_scan)
    for name in h_loop:
        np.testing.assert_allclose(
            np.asarray(h_loop[name]),
            np.asarray(h_scan[name]),
            rtol=1e-5,
            atol=1e-5,
            err_msg=f"{policy}/{name}",
        )


def test_run_scanned_advances_state_like_loop():
    cfg = _cfg()
    a, b = FedFogSimulator(cfg), FedFogSimulator(cfg)
    a.run()
    b.run_scanned()
    for pa, pb in zip(
        jnp.ravel(a.params[0]["w"])[:32], jnp.ravel(b.params[0]["w"])[:32]
    ):
        np.testing.assert_allclose(float(pa), float(pb), rtol=1e-5, atol=1e-6)
    assert int(a.sched_state.round_index) == int(b.sched_state.round_index) == 4


# --------------------------------------------------------------------- #
# (b) sweep: deterministic, and seed-sliced ≡ standalone runs
# --------------------------------------------------------------------- #
def test_sweep_is_seed_deterministic():
    cfg = _cfg()
    r1 = run_sweep(cfg, seeds=[0, 1], axes={"policy": ["fedfog", "rcs"]})
    r2 = run_sweep(cfg, seeds=[0, 1], axes={"policy": ["fedfog", "rcs"]})
    assert r1.configs == r2.configs
    for name in r1.history:
        np.testing.assert_array_equal(r1.history[name], r2.history[name])
    # different seeds genuinely differ
    assert not np.array_equal(
        r1.metric("accuracy")[:, 0], r1.metric("accuracy")[:, 1]
    )


def test_sweep_matches_standalone_scanned_runs():
    cfg = _cfg()
    seeds = [0, 3]
    res = run_sweep(cfg, seeds=seeds, cases=[{"policy": "fedfog"}, {"top_k": 2}])
    assert res.metric("accuracy").shape == (2, 2, cfg.rounds)
    for g, overrides in enumerate(res.configs):
        for si, s in enumerate(seeds):
            h = FedFogSimulator(
                dataclasses.replace(cfg, seed=s, **overrides)
            ).run_scanned()
            for name in ("accuracy", "round_latency_ms", "energy_j",
                         "cold_starts", "num_selected"):
                np.testing.assert_allclose(
                    res.metric(name)[g, si],
                    np.asarray(h[name]),
                    rtol=1e-5,
                    atol=1e-5,
                    err_msg=f"{overrides}/seed{s}/{name}",
                )


def test_sweep_devices_sharding_bit_identical():
    """run_sweep(devices=N) — including the seed-padding path where
    |seeds| is not a multiple of N — reproduces the single-device sweep
    bit-for-bit. Subprocess: the fake-device count must be set before
    jax initializes."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    + os.environ.get("XLA_FLAGS", "")
)
import numpy as np
from repro.fl.simulator import SimulatorConfig
from repro.sim import run_sweep

cfg = SimulatorConfig(task="emnist", num_clients=8, rounds=3, top_k=4,
                      hidden=(16,), seed=0)
for seeds in ([0, 1, 2], [0, 1, 2, 3], [0, 1, 2, 3, 4, 5]):
    a = run_sweep(cfg, seeds=seeds)
    b = run_sweep(cfg, seeds=seeds, devices=4)
    for k in a.history:
        assert np.array_equal(a.history[k], b.history[k]), (len(seeds), k)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=repo, timeout=600,
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, (
        proc.stdout[-1000:], proc.stderr[-1000:]
    )


def test_grouped_sweep_bitwise_matches_per_point():
    """Compile-once grouping is a pure execution-strategy change: on a
    mixed structural×numeric grid (policies change the trace; lr / top_k
    / Eq. 3 thresholds are lifted to vmapped data) the grouped sweep must
    reproduce the per-grid-point sweep BITWISE."""
    from repro.core.scheduler import SchedulerConfig

    cfg = _cfg(rounds=3)
    cases = [
        {"policy": "fedfog", "lr": 0.03},
        {"policy": "fedfog", "lr": 0.07},
        {"policy": "fedfog", "lr": 0.03, "top_k": 2},
        {"policy": "rcs", "lr": 0.05},
        {"scheduler": SchedulerConfig(theta_h=0.5, theta_e=0.4)},
        {"scheduler": SchedulerConfig(theta_h=0.7, theta_e=0.6)},
    ]
    from repro.sim import clear_compile_cache

    clear_compile_cache()  # count this call's compiles, not stale hits
    tm: dict = {}
    grouped = run_sweep(cfg, seeds=[0, 1], cases=cases, timings=tm)
    per_point = run_sweep(cfg, seeds=[0, 1], cases=cases, group=False)
    # fedfog cases (lr/top_k/theta lifted) collapse into one group, rcs
    # into another — strictly fewer compiled programs than grid points
    assert tm["n_compiles"] < len(cases)
    assert tm["cache_hits"] == 0
    assert grouped.configs == per_point.configs
    for name in grouped.history:
        np.testing.assert_array_equal(
            grouped.history[name], per_point.history[name], err_msg=name
        )


def test_sweep_compile_cache_reuse():
    """A structurally-identical second sweep replays cached executables:
    zero new compiles, bit-identical histories."""
    from repro.sim import clear_compile_cache

    cfg = _cfg(rounds=3)
    axes = {"lr": [0.02, 0.05, 0.08]}
    clear_compile_cache()
    tm1: dict = {}
    r1 = run_sweep(cfg, seeds=[0, 1], axes=axes, timings=tm1)
    tm2: dict = {}
    r2 = run_sweep(cfg, seeds=[0, 1], axes=axes, timings=tm2)
    assert tm1["n_compiles"] == 1  # one structural group for the lr grid
    assert tm2["n_compiles"] == 0 and tm2["cache_hits"] == 1
    assert tm2["compile_s"] == 0.0
    for name in r1.history:
        np.testing.assert_array_equal(r1.history[name], r2.history[name])


def test_aot_scanned_matches_run_scanned():
    """aot_scanned + run_scanned_with reproduce run_scanned bitwise —
    including on a DIFFERENT same-shape simulator instance (the sharing
    that lets benchmarks compile the scan program once per seed sweep)."""
    cfg = _cfg(rounds=3)
    exe = FedFogSimulator(cfg).aot_scanned()
    for s in range(2):
        c = dataclasses.replace(cfg, seed=s)
        a = FedFogSimulator(c).run_scanned()
        b = FedFogSimulator(c).run_scanned_with(exe)
        assert set(a) == set(b)
        for name in a:
            np.testing.assert_array_equal(
                np.asarray(a[name]), np.asarray(b[name]), err_msg=name
            )


def test_sweep_signature_aggregator_structural_trim_lifted():
    """Compile-cache keys must distinguish the kernel gate STRUCTURALLY:
    ``aggregator`` and ``use_pallas_agg`` each open a new compile group,
    while ``trim_fraction`` is numeric data lifted into the vmapped
    batch — two trim fractions share one executable. Grouped results
    stay bitwise-equal to the per-point sweep."""
    from repro.sim import clear_compile_cache

    cfg = _cfg(rounds=2)
    cases = [
        {"aggregator": "trimmed", "trim_fraction": 0.1},
        {"aggregator": "trimmed", "trim_fraction": 0.2},  # same group
        {"aggregator": "median"},  # new structural group
        {"use_pallas_agg": True},  # kernel routing is structural too
    ]
    clear_compile_cache()
    tm: dict = {}
    grouped = run_sweep(cfg, seeds=[0], cases=cases, timings=tm)
    assert tm["n_compiles"] == 3, tm  # trimmed×2 collapse into one
    per_point = run_sweep(cfg, seeds=[0], cases=cases, group=False)
    assert grouped.configs == per_point.configs
    for name in grouped.history:
        np.testing.assert_array_equal(
            grouped.history[name], per_point.history[name], err_msg=name
        )


def test_round_pallas_agg_matches_reference():
    """use_pallas_agg routes Eq. 6 + server apply through the fused
    kernel (interpret mode on CPU); a full multi-round run must agree
    with the reference fedavg_stacked path to float tolerance, and the
    kernel itself must agree with fedavg_apply_ref on round-shaped
    inputs."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fedavg import fedavg_apply, fedavg_apply_ref

    cfg = _cfg(rounds=3)
    h_ref = FedFogSimulator(cfg).run_scanned()
    h_pal = FedFogSimulator(
        dataclasses.replace(cfg, use_pallas_agg=True)
    ).run_scanned()
    for name in h_ref:
        np.testing.assert_allclose(
            np.asarray(h_ref[name]), np.asarray(h_pal[name]),
            rtol=1e-5, atol=1e-5, err_msg=name,
        )
    # direct kernel-vs-oracle cross-check at simulator shapes
    key = jax.random.PRNGKey(3)
    upd = jax.random.normal(key, (cfg.num_clients, 16 * 62))
    base = jax.random.normal(jax.random.fold_in(key, 1), (16 * 62,))
    mask = jnp.arange(cfg.num_clients) < 4
    sizes = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2),
                                      (cfg.num_clients,))) * 100
    out = fedavg_apply(upd, base, mask, sizes, lr=0.7)
    ref = fedavg_apply_ref(upd, base, mask, sizes, lr=0.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_sweep_reductions_shapes():
    cfg = _cfg(rounds=3)
    res = run_sweep(cfg, seeds=[0, 1, 2])
    mean, ci = res.mean_ci("accuracy")
    assert mean.shape == ci.shape == (1, 3)
    m, s = res.mean_std("energy_j", reduce="sum")
    assert m.shape == s.shape == (1,)
    stats = res.stats(0)
    assert stats["final_accuracy"].shape == (3,)
    np.testing.assert_allclose(
        stats["total_energy_j"], res.metric("energy_j")[0].sum(axis=-1)
    )


# --------------------------------------------------------------------- #
# (c) shared cost model reproduces the seed formulas for both engines
# --------------------------------------------------------------------- #
def _fixture(n=16, seed=0):
    prof = make_profiles(TelemetryConfig(num_clients=n, seed=seed))
    rng = np.random.RandomState(seed)
    selected = jnp.asarray(rng.rand(n) < 0.6)
    warm = jnp.asarray(rng.rand(n) < 0.5)
    return prof, selected, warm


def test_cost_model_times_reproduce_seed_formula():
    cfg = FaasSimConfig()
    prof, selected, warm = _fixture()
    n = selected.shape[0]
    workload, up, down = 1e9, 1e6, 2e6
    for policy in ("fedfog", "fogfaas"):
        per, rnd, orch = round_times_ms(
            cfg, prof, selected, warm, workload, up, down, policy=policy
        )
        # seed formula, per-client orchestration share included
        k = float(jnp.sum(selected))
        t_comp = workload / prof.mips * 1e3
        t_net = (up / prof.bw_up + down / prof.bw_down) * 1e3 + prof.rtt_ms
        delta = jnp.where(
            warm, cfg.cold_start.delta_warm_ms, cfg.cold_start.delta_cold_ms
        )
        if policy == "fedfog":
            orch_ref = cfg.sort_ms_per_nlogn * n * np.log2(n) + cfg.dispatch_ms * k
        else:
            orch_ref = cfg.deploy_ms * n + cfg.poll_ms * n * n
        per_ref = (delta + t_comp + t_net + orch_ref / max(k, 1.0)) * selected
        np.testing.assert_allclose(np.asarray(per), np.asarray(per_ref), rtol=1e-5)
        np.testing.assert_allclose(float(orch), float(orch_ref), rtol=1e-5)
        np.testing.assert_allclose(
            float(rnd), float(np.asarray(per_ref).max()), rtol=1e-5
        )


def test_per_client_latency_masked_for_unselected():
    cfg = FaasSimConfig()
    prof, selected, warm = _fixture()
    per, _, _ = round_times_ms(cfg, prof, selected, warm, 1e9, 1e6, 2e6)
    np.testing.assert_array_equal(
        np.asarray(per)[~np.asarray(selected)], 0.0
    )
    assert (np.asarray(per)[np.asarray(selected)] > 0).all()


def test_cost_model_energy_reproduces_both_engine_formulas():
    cfg = FaasSimConfig()
    prof, selected, warm = _fixture()
    workload, up = 1e9, 1e6
    e = RoundCostModel(cfg).energy_j(selected, warm, workload, up)
    # paper-scale engine formula (seed sim/faas.py)
    e_faas = round_energy_j(cfg, prof, selected, warm, workload, up)
    np.testing.assert_allclose(np.asarray(e), np.asarray(e_faas), rtol=1e-6)
    # pod-scale engine formula (seed fl/round.py inline expression)
    em = cfg.energy
    sel_f = np.asarray(selected, np.float32)
    e_pod = sel_f * (em.c_cpu * workload + em.c_tx * up) + (
        np.asarray(selected) & ~np.asarray(warm)
    ) * em.cold_start_energy_j
    np.testing.assert_allclose(np.asarray(e), e_pod, rtol=1e-6)


def test_round_costs_bundle_consistency():
    cfg = FaasSimConfig()
    prof, selected, warm = _fixture()
    costs = RoundCostModel(cfg).round_costs(
        prof, selected, warm, 1e9, 1e6, 2e6, policy="fedfog"
    )
    per, rnd, orch = round_times_ms(cfg, prof, selected, warm, 1e9, 1e6, 2e6)
    np.testing.assert_allclose(np.asarray(costs.per_client_ms), np.asarray(per))
    np.testing.assert_allclose(float(costs.round_ms), float(rnd))
    np.testing.assert_allclose(float(costs.orchestration_ms), float(orch))
    assert int(costs.cold_starts) == int(
        np.sum(np.asarray(selected) & ~np.asarray(warm))
    )


def test_cost_model_from_scheduler_matches_faas_defaults():
    from repro.core.scheduler import SchedulerConfig

    m = RoundCostModel.from_scheduler(SchedulerConfig())
    assert m.cfg.energy == FaasSimConfig().energy
    assert m.cfg.cold_start == FaasSimConfig().cold_start
