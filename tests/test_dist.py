"""Unit tests for the repro.dist distribution layer.

Covers the HLO collective parser (explicit + iota replica groups, loop
warnings, dot flops), the axis-crossing classifier, scaled mesh plans,
and the divisibility fallbacks of the sharding rule table. The
end-to-end fake-device round lives in tests/test_sharded_round.py.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.dist import analyze_hlo, count_axis_crossing, make_rules, plan_for
from repro.models import build_model

# --------------------------------------------------------------------- #
# analyze_hlo on synthetic HLO text
# --------------------------------------------------------------------- #
SYNTH_HLO = """\
HloModule jit_round, entry_computation_layout={(f32[8,16]{1,0})->f32[8,16]{1,0}}

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(f32[] %x, f32[] %y)
}

%body (p: (f32[8,16], s32[])) -> (f32[8,16], s32[]) {
  %p = (f32[8,16]{1,0}, s32[]) parameter(0)
  %gte = f32[8,16]{1,0} get-tuple-element((f32[8,16]{1,0}, s32[]) %p), index=0
  %cp = f32[8,16]{1,0} collective-permute(f32[8,16]{1,0} %gte), source_target_pairs={{0,1},{1,0}}
  %i = s32[] get-tuple-element((f32[8,16]{1,0}, s32[]) %p), index=1
  ROOT %tup = (f32[8,16]{1,0}, s32[]) tuple(f32[8,16]{1,0} %cp, s32[] %i)
}

%cond (p: (f32[8,16], s32[])) -> pred[] {
  %p = (f32[8,16]{1,0}, s32[]) parameter(0)
  %i = s32[] get-tuple-element((f32[8,16]{1,0}, s32[]) %p), index=1
  %c = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (arg0: f32[8,16]) -> f32[8,16] {
  %arg0 = f32[8,16]{1,0} parameter(0)
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(f32[8,16]{1,0} %arg0, f32[16,16]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %d), replica_groups={{0,2},{1,3}}, to_apply=%add.clone
  %ag = f32[16,16]{1,0} all-gather(f32[8,16]{1,0} %ar), replica_groups=[2,2]<=[4], dimensions={0}
  %rs = bf16[4,16]{1,0} reduce-scatter(bf16[4,16]{1,0} %ar), replica_groups={{0,1},{2,3}}, dimensions={0}, to_apply=%add.clone
  %t0 = (f32[8,16]{1,0}, s32[]) tuple(f32[8,16]{1,0} %ar, s32[] %arg0)
  %wh = (f32[8,16]{1,0}, s32[]) while((f32[8,16]{1,0}, s32[]) %t0), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element((f32[8,16]{1,0}, s32[]) %wh), index=0
}
"""


def test_analyze_hlo_counts_and_bytes():
    a = analyze_hlo(SYNTH_HLO)
    counts = a.collectives.count_by_kind
    assert counts == {
        "all-reduce": 1,
        "all-gather": 1,
        "reduce-scatter": 1,
        "collective-permute": 1,
    }
    by = a.collectives.bytes_by_kind
    assert by["all-reduce"] == 8 * 16 * 4
    assert by["all-gather"] == 16 * 16 * 4
    assert by["reduce-scatter"] == 4 * 16 * 2  # bf16
    # dot: 2 * M*N * K = 2 * 8*16 * 16
    assert a.dot_flops == 2 * 8 * 16 * 16


def test_analyze_hlo_replica_groups():
    a = analyze_hlo(SYNTH_HLO)
    ops = {op.kind: op for op in a.collectives.ops}
    assert ops["all-reduce"].groups == [[0, 2], [1, 3]]
    # iota form [2,2]<=[4] -> [[0,1],[2,3]]
    assert ops["all-gather"].groups == [[0, 1], [2, 3]]
    assert ops["collective-permute"].groups == [[0, 1], [1, 0]]


def test_analyze_hlo_loop_body_warning():
    a = analyze_hlo(SYNTH_HLO)
    warns = a.collectives.trip_count_warnings
    assert len(warns) == 1 and "collective-permute" in warns[0]
    assert "body" in warns[0]


def test_analyze_hlo_iota_transpose():
    text = (
        "ENTRY %main (p0: f32[4]) -> f32[4] {\n"
        "  %p0 = f32[4]{0} parameter(0)\n"
        "  ROOT %ar = f32[4]{0} all-reduce(f32[4]{0} %p0), "
        "replica_groups=[2,2]<=[2,2]T(1,0), to_apply=%add\n"
        "}\n"
    )
    a = analyze_hlo(text)
    (op,) = a.collectives.ops
    # iota over [2,2] transposed: ids [[0,2],[1,3]]
    assert op.groups == [[0, 2], [1, 3]]


def _fake_mesh(shape: dict):
    return types.SimpleNamespace(
        axis_names=tuple(shape), shape=dict(shape)
    )


def test_count_axis_crossing():
    a = analyze_hlo(SYNTH_HLO)
    # mesh (client=2, zero=2), row-major ids: client coord = id // 2.
    mesh = _fake_mesh({"client": 2, "zero": 2})
    # all-reduce groups [[0,2],[1,3]] differ in client coord -> crossing.
    assert count_axis_crossing(a, mesh, axes=("client",)) == 1
    # all-gather groups [[0,1],[2,3]] stay within one client row.
    assert (
        count_axis_crossing(a, mesh, axes=("zero",), kinds=("all-gather",))
        == 1
    )
    assert (
        count_axis_crossing(a, mesh, axes=("client",), kinds=("all-gather",))
        == 0
    )
    # byte filter drops the 512 B all-reduce
    assert (
        count_axis_crossing(a, mesh, axes=("client",), min_bytes=1e6) == 0
    )


def test_analyze_hlo_on_real_compile():
    """The parser handles whatever the current CPU backend emits."""
    f = jax.jit(lambda x, w: jnp.tanh(x @ w).sum())
    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16, 4), jnp.float32)
    a = analyze_hlo(f.lower(x, w).compile().as_text())
    assert a.num_instructions > 0
    assert a.collectives.total_bytes == 0  # single device
    assert a.dot_flops >= 2 * 8 * 4 * 16


# --------------------------------------------------------------------- #
# Mesh plans
# --------------------------------------------------------------------- #
def test_scaled_plan_arithmetic():
    cfg = get_config("llama3.2-1b")
    plan = plan_for(cfg, device_count=8)
    assert plan.device_count == 8
    assert plan.num_clients * plan.zero == 8
    assert plan.model_split == (1, 1)
    assert plan.client_axes == ("client",)
    assert plan.data_axes == ("client", "zero")

    plan = plan_for(cfg, device_count=8, zero=4)
    assert plan.zero == 4 and plan.num_clients == 2

    with pytest.raises(ValueError):
        plan_for(cfg, device_count=8, zero=3)
    with pytest.raises(ValueError):
        plan_for(cfg, device_count=7, multi_pod=True)


def test_multi_pod_plan_axes():
    cfg = get_config("qwen2.5-14b")
    plan = plan_for(cfg, multi_pod=True)
    assert plan.axis_names[0] == "pod"
    assert plan.shape["pod"] == 2
    assert plan.device_count == 512
    assert plan.client_axes == ("pod", "client")
    # qwen: 40 heads -> tp=8, sp=2
    assert plan.model_axes == ("tp", "sp")
    assert plan.model_split == (8, 2)


def test_moe_plan_expert_axis():
    plan = plan_for(get_config("mixtral-8x7b"))
    assert plan.model_axes == ("expert", "tp")
    assert plan.model_split == (8, 2)
    plan = plan_for(get_config("moonshot-v1-16b-a3b"))
    assert plan.model_split == (16, 1)


# --------------------------------------------------------------------- #
# Sharding rule fallbacks
# --------------------------------------------------------------------- #
def test_rules_divisibility_fallback():
    """GQA kv heads smaller than tp fall back to replication; every spec
    entry's axis product divides its dim by construction."""
    cfg = get_config("yi-9b")  # 32 q heads (tp=16), only 4 kv heads
    plan = plan_for(cfg)
    from repro.dist.sharding import ShardingRules

    rules = ShardingRules.__new__(ShardingRules)
    object.__setattr__(rules, "cfg", cfg)
    object.__setattr__(rules, "plan", plan)
    object.__setattr__(
        rules, "mesh", _fake_mesh({k: v for k, v in plan.shape.items() if v > 1})
    )
    model = build_model(cfg)
    specs = rules.param_specs(model.param_shapes(), model.param_axes())
    flat = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    layer_specs = specs["layers"]
    # q heads sharded over tp; kv heads replicated (4 % 16 != 0)
    assert layer_specs["wq"][2] == "tp"
    assert layer_specs["wk"][2] is None
    # FSDP: embed dims over zero
    assert layer_specs["wq"][1] == "zero"
    # every entry divides
    flat_shapes = jax.tree.leaves(model.param_shapes())
    for sds, spec in zip(flat_shapes, flat):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([plan.shape[a] for a in axes]))
            assert sds.shape[i] % prod == 0


def test_rules_serve_fsdp_off():
    cfg = get_reduced("llama3.2-1b")
    rules = make_rules(None, cfg, device_count=1)
    model = build_model(cfg)
    shapes, laxes = model.param_shapes(), model.param_axes()
    # device_count=1: everything replicated either way
    specs = rules.param_specs(shapes, laxes, fsdp=False)
    for s in jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]:
        assert all(e is None for e in s)


def test_rules_stacked_prepends_client_axis():
    cfg = get_config("llama3.2-1b")
    plan = plan_for(cfg)
    from repro.dist.sharding import ShardingRules

    rules = ShardingRules.__new__(ShardingRules)
    object.__setattr__(rules, "cfg", cfg)
    object.__setattr__(rules, "plan", plan)
    object.__setattr__(
        rules, "mesh", _fake_mesh({k: v for k, v in plan.shape.items() if v > 1})
    )
    model = build_model(cfg)
    specs = rules.param_specs(
        model.param_shapes(), model.param_axes(), stacked=True
    )
    for s in jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]:
        assert s[0] == "client"
