"""Event-driven async engine: queue ordering, sync equivalence, sweeps,
staleness weighting, and churn.

Acceptance contracts (ISSUE 3):
  (a) the event queue pops in time order under random push/pop sequences
      inside jit;
  (b) an async run with an unbounded buffer, no churn, and zero staleness
      discount matches the ``run_scanned()`` accuracy trajectory to float
      tolerance;
  (c) ``run_sweep(engine="async")`` is deterministic per seed, and seed s
      of a sweep reproduces a standalone async run.
Plus hypothesis property tests for the staleness-discounted Eq. 6
generalization (weights in (0,1], monotone non-increasing, exact FedAvg
reduction at zero staleness).
"""
import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import fedavg_stacked
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim import run_sweep
from repro.sim.events import (
    AsyncConfig,
    AsyncFedFogSimulator,
    ChurnConfig,
    KIND_COMPLETE,
    async_aggregate,
    available_mask,
    make_queue,
    pop_event,
    push_event,
    push_events,
    stale_discount,
    staleness_weights,
    step_churn,
)
from repro.sim.events.queue import cancel_events


def _cfg(**kw) -> SimulatorConfig:
    base = dict(
        task="emnist", num_clients=8, rounds=4, top_k=4, hidden=(16,), seed=0
    )
    base.update(kw)
    return SimulatorConfig(**base)


# --------------------------------------------------------------------- #
# (a) event queue: time-ordered pops inside jit
# --------------------------------------------------------------------- #
def test_queue_pops_sorted_inside_jit():
    """Push a random batch inside one jitted program, pop everything:
    pop order must be ascending in time and a permutation of the input."""
    rng = np.random.RandomState(0)
    times = rng.uniform(0, 100, size=24).astype(np.float32)

    @jax.jit
    def run(times):
        q = make_queue(32)
        q = push_events(
            q, times, jnp.arange(24), jnp.zeros(24, jnp.int32),
            jnp.zeros(24), jnp.ones(24, bool),
        )

        def body(q, _):
            ev, q = pop_event(q)
            return q, (ev.time, ev.client, ev.valid)

        _, (t, c, v) = jax.lax.scan(body, q, None, length=32)
        return t, c, v

    t, c, v = jax.device_get(run(jnp.asarray(times)))
    assert v[:24].all() and not v[24:].any()
    assert (np.diff(t[:24]) >= 0).all(), "pops must be time-ordered"
    np.testing.assert_allclose(np.sort(times), t[:24], rtol=1e-6)
    # client ids rode along with their times
    np.testing.assert_array_equal(np.argsort(times, kind="stable"), c[:24])


def test_queue_random_interleaved_push_pop_matches_heapq():
    """Random interleaving of jitted push/pop tracks a reference heap."""
    push_j = jax.jit(push_event)
    pop_j = jax.jit(pop_event)
    rng = np.random.RandomState(1)
    q, heap, counter = make_queue(64), [], 0
    for _ in range(200):
        if heap and rng.rand() < 0.45:
            ev, q = pop_j(q)
            t_ref, _, c_ref = heapq.heappop(heap)
            assert bool(ev.valid)
            np.testing.assert_allclose(float(ev.time), t_ref, rtol=1e-6)
            assert int(ev.client) == c_ref
        else:
            t = float(np.float32(rng.uniform(0, 1000)))
            q = push_j(q, t, counter, 0, 0.0, True)
            # heap tie-break mirrors the queue: FIFO among equal times
            heapq.heappush(heap, (t, counter, counter))
            counter += 1
    ev, q = pop_j(q)  # drain check: remaining pops still ordered
    while heap:
        t_ref, _, c_ref = heapq.heappop(heap)
        np.testing.assert_allclose(float(ev.time), t_ref, rtol=1e-6)
        ev, q = pop_j(q)
    assert not bool(ev.valid)  # empty queue pops invalid


def test_queue_overflow_counts_drops():
    q = make_queue(4)
    for i in range(6):
        q = push_event(q, float(i), i, 0)
    assert int(q.dropped) == 2
    assert int(jnp.sum(q.valid)) == 4


def test_pop_batch_matches_sequential_pops():
    """``pop_batch(q, k)`` must free exactly the slots ``k`` successive
    ``pop_event`` calls would — including duplicate-time tie-breaks —
    and report the last popped event's time."""
    from repro.sim.events.queue import pop_batch

    rng = np.random.RandomState(7)
    times = rng.choice([1.0, 2.0, 2.0, 3.0, 5.0, 5.0, 5.0, 8.0], 20)
    q = make_queue(32)
    q = push_events(
        q, jnp.asarray(times, jnp.float32), jnp.arange(20),
        jnp.zeros(20, jnp.int32), jnp.zeros(20), jnp.ones(20, bool),
    )
    for take in (1, 3, 7, 20, 25):
        popped, t_last, q2 = pop_batch(q, take)
        qs, last_t = q, None
        for _ in range(min(take, 20)):
            ev, qs = pop_event(qs)
            assert bool(ev.valid)
            last_t = float(ev.time)
        np.testing.assert_array_equal(
            np.asarray(q2.valid), np.asarray(qs.valid), err_msg=f"take={take}"
        )
        np.testing.assert_array_equal(
            np.asarray(popped), np.asarray(q.valid) & ~np.asarray(qs.valid)
        )
        assert float(t_last) == last_t


def test_queue_cancel_events():
    q = make_queue(8)
    q = push_events(
        q, jnp.arange(4.0), jnp.arange(4), jnp.full(4, KIND_COMPLETE),
        jnp.zeros(4), jnp.ones(4, bool),
    )
    kill = jnp.asarray([False, True, False, True])
    q = cancel_events(q, kill, KIND_COMPLETE)
    ev0, q = pop_event(q)
    ev1, q = pop_event(q)
    ev2, _ = pop_event(q)
    assert (int(ev0.client), int(ev1.client)) == (0, 2)
    assert not bool(ev2.valid)


def _check_cancel_then_pop(seed: int, kill_kind: int) -> None:
    """Batch-push + cancel_events under jit vs a heapq oracle: a
    cancel-then-drain sequence never pops a cancelled (client, kind)
    event, and the survivors pop in exactly the oracle's order."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, 25))
    times = rng.uniform(0, 100, n).astype(np.float32)
    clients = rng.randint(0, 8, n).astype(np.int32)
    kinds = rng.randint(0, 2, n).astype(np.int32)
    kill = rng.rand(8) < 0.4

    @jax.jit
    def run(times, clients, kinds, kill):
        q = make_queue(32)
        q = push_events(
            q, times, clients, kinds, jnp.zeros(n), jnp.ones(n, bool)
        )
        q = cancel_events(q, kill, kill_kind)

        def body(q, _):
            ev, q = pop_event(q)
            return q, (ev.time, ev.client, ev.kind, ev.valid)

        _, out = jax.lax.scan(body, q, None, length=32)
        return out

    t, c, k, v = jax.device_get(
        run(
            jnp.asarray(times), jnp.asarray(clients),
            jnp.asarray(kinds), jnp.asarray(kill),
        )
    )
    cancelled = kill[clients] & (kinds == kill_kind)
    # oracle: surviving events in (time, push-order) heap order
    heap = [
        (times[i], i, clients[i], kinds[i])
        for i in range(n)
        if not cancelled[i]
    ]
    heapq.heapify(heap)
    n_live = len(heap)
    assert int(v.sum()) == n_live, "cancel freed the wrong slot count"
    for j in range(n_live):
        t_ref, _, c_ref, k_ref = heapq.heappop(heap)
        assert v[j]
        assert not (kill[c[j]] and k[j] == kill_kind), (
            f"popped a cancelled event at pop {j}"
        )
        np.testing.assert_allclose(t[j], t_ref, rtol=1e-6)
        assert (c[j], k[j]) == (c_ref, k_ref)
    assert not v[n_live:].any()


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("kill_kind", (0, 1))
def test_cancel_then_pop_matches_heapq_oracle(seed, kill_kind):
    """Fixed-seed slice of the cancel/pop property — always runs; the
    hypothesis variant below widens the search when the dep is present."""
    _check_cancel_then_pop(seed, kill_kind)


# --------------------------------------------------------------------- #
# (b) sync recovery: cohort-mode async == scan-compiled sync engine
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", ("fedfog", "fogfaas"))
def test_async_cohort_mode_matches_run_scanned(policy):
    cfg = _cfg(policy=policy, rounds=5)
    h_sync = FedFogSimulator(cfg).run_scanned()
    h_async = AsyncFedFogSimulator(
        cfg,
        AsyncConfig(staleness_exponent=0.0),  # unbounded buffer, no churn
    ).run()
    assert h_async["num_flushes"] == cfg.rounds
    np.testing.assert_allclose(
        h_async["accuracy"], h_sync["accuracy"], rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        h_async["update_latency_ms"], h_sync["round_latency_ms"],
        rtol=1e-5, atol=1e-3,
    )
    np.testing.assert_allclose(
        h_async["energy_j"], h_sync["energy_j"], rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        h_async["cold_starts"], h_sync["cold_starts"], atol=0
    )
    np.testing.assert_allclose(
        h_async["num_aggregated"], h_sync["num_selected"], atol=0
    )
    assert all(s == 0.0 for s in h_async["mean_staleness"])


def test_async_buffer_k_registry_sized_still_matches_sync():
    """buffer_k = N never count-triggers (cohorts are top_k < N), so the
    idle flush carries it — identical to the unbounded-buffer config."""
    cfg = _cfg(rounds=4)
    h_sync = FedFogSimulator(cfg).run_scanned()
    h_async = AsyncFedFogSimulator(
        cfg,
        AsyncConfig(buffer_k=cfg.num_clients, staleness_exponent=0.0),
    ).run()
    np.testing.assert_allclose(
        h_async["accuracy"], h_sync["accuracy"], rtol=1e-5, atol=1e-5
    )


def test_async_interval_mode_accrues_staleness():
    """Overlapping cohorts (fast dispatch cadence + straggler tail) must
    produce genuinely stale aggregations — the thing sync cannot model."""
    sim = AsyncFedFogSimulator(
        _cfg(rounds=12),
        AsyncConfig.fedasync(
            dispatch_interval_ms=200.0, straggler_sigma=0.5
        ),
    )
    h = sim.run()
    assert h["num_flushes"] > 0
    assert max(h["mean_staleness"]) > 0, "no staleness under overlap?"
    assert all(s >= 0 for s in h["mean_staleness"])


def test_async_fedbuff_flush_sizes():
    k = 3
    h = AsyncFedFogSimulator(
        _cfg(rounds=8, top_k=6),
        AsyncConfig.fedbuff(k, dispatch_interval_ms=500.0),
    ).run()
    sizes = h["num_aggregated"]
    assert sizes, "no flushes"
    # count-triggered flushes hold exactly k; idle flushes hold < k
    assert all(s <= k for s in sizes)
    assert any(s == k for s in sizes)
    assert sum(sizes) == h["num_completions"]


@pytest.mark.parametrize(
    "acfg",
    [
        AsyncConfig(staleness_exponent=0.0),  # cohort / sync-recovery
        AsyncConfig.fedasync(dispatch_interval_ms=200.0, straggler_sigma=0.5),
        AsyncConfig.fedbuff(
            3, dispatch_interval_ms=300.0, straggler_sigma=0.4,
            churn=ChurnConfig(arrival_rate=0.2, departure_rate=0.8),
        ),
    ],
    ids=("cohort", "fedasync", "fedbuff-churn"),
)
def test_coalesced_matches_single_pop_bitwise(acfg):
    """Coalesced batched stepping is a pure execution-strategy change:
    trajectories must match the one-pop-per-step oracle BITWISE — same
    flush metrics, same queue-drop counters — in every server mode,
    including same-timestamp tie-breaks (slot order) and mid-batch
    ``buffer_k`` flush boundaries."""
    import jax

    cfg = _cfg(rounds=4)
    fast = AsyncFedFogSimulator(cfg, dataclasses.replace(acfg, coalesce=True))
    oracle = AsyncFedFogSimulator(cfg, dataclasses.replace(acfg, coalesce=False))
    out_f = jax.device_get(jax.jit(fast.metrics_for_seed)(0))
    out_o = jax.device_get(jax.jit(oracle.metrics_for_seed)(0))
    assert set(out_f) == set(out_o)
    for name in out_f:
        np.testing.assert_array_equal(
            np.asarray(out_f[name]), np.asarray(out_o[name]), err_msg=name
        )


def test_flush_cold_starts_conserved():
    """Regression: flush metrics must not re-attribute a dispatch's cold
    starts to every flush it feeds (FedAsync flushes once per completion,
    so the old `last_cold` snapshot was counted up to top_k times).
    Cold starts are consumed by the first flush after the dispatch:
    Σ flush cold_starts == Σ dispatch cold_starts."""
    h = AsyncFedFogSimulator(
        _cfg(rounds=6, top_k=6),
        AsyncConfig.fedasync(dispatch_interval_ms=1e9),  # sequential cohorts
    ).run()
    assert h["num_flushes"] > h["num_dispatches"], "need repeat flushes"
    assert sum(h["dispatch_cold_starts"]) > 0
    assert sum(h["cold_starts"]) == sum(h["dispatch_cold_starts"])


# --------------------------------------------------------------------- #
# (c) async sweeps: deterministic, seed-sliced == standalone
# --------------------------------------------------------------------- #
def test_async_sweep_deterministic_and_matches_standalone():
    cfg = _cfg(rounds=3)
    acfg = AsyncConfig.fedbuff(2, dispatch_interval_ms=800.0,
                               straggler_sigma=0.2)
    seeds = [0, 2]
    kw = dict(engine="async", async_cfg=acfg, axes={"buffer_k": [1, 2]})
    r1 = run_sweep(cfg, seeds=seeds, **kw)
    r2 = run_sweep(cfg, seeds=seeds, **kw)
    for name in r1.history:
        np.testing.assert_array_equal(r1.history[name], r2.history[name])
    # different seeds genuinely differ
    assert not np.array_equal(
        r1.metric("accuracy")[:, 0], r1.metric("accuracy")[:, 1]
    )
    for g, overrides in enumerate(r1.configs):
        for si, s in enumerate(seeds):
            h = AsyncFedFogSimulator(
                dataclasses.replace(cfg, seed=s),
                dataclasses.replace(
                    acfg, max_dispatches=cfg.rounds, **overrides
                ),
            ).run()
            nf = h["num_flushes"]
            valid = r1.metric("valid")[g, si]
            assert valid[:nf].all() and not valid[nf:].any()
            for name in ("accuracy", "t_ms", "num_aggregated", "energy_j",
                         "mean_staleness"):
                np.testing.assert_allclose(
                    r1.metric(name)[g, si, :nf],
                    np.asarray(h[name]),
                    rtol=1e-5, atol=1e-5,
                    err_msg=f"{overrides}/seed{s}/{name}",
                )
            # final() must be valid-aware: last real flush, not padding
            np.testing.assert_allclose(
                r1.final("accuracy")[g, si], h["accuracy"][-1],
                rtol=1e-5, atol=1e-5,
            )


def test_async_sweep_surfaces_queue_overflow():
    cfg = _cfg(num_clients=6, rounds=3, top_k=6, hidden=(8,))
    with pytest.raises(RuntimeError, match="overflow"):
        run_sweep(
            cfg, seeds=[0], engine="async",
            async_cfg=AsyncConfig(queue_capacity=2),
        )


def test_async_sweep_respects_async_cfg_dispatch_budget():
    """async_cfg.max_dispatches wins when no rounds= argument is given."""
    cfg = _cfg(rounds=6)
    res = run_sweep(
        cfg, seeds=[0], engine="async",
        async_cfg=AsyncConfig(max_dispatches=2),
    )
    assert int((res.metric("valid")[0, 0] > 0).sum()) == 2
    # explicit rounds= still overrides
    res2 = run_sweep(
        cfg, seeds=[0], rounds=3, engine="async",
        async_cfg=AsyncConfig(max_dispatches=2),
    )
    assert int((res2.metric("valid")[0, 0] > 0).sum()) == 3


def test_flush_keys_decorrelate_repeat_flushes():
    """DP noise must be an independent draw per flush between dispatches
    (FedAsync flushes once per completion): with lr=0 the client deltas
    are exactly zero, so each flush's param change IS its DP noise draw —
    drive the handlers eagerly and require different draws."""
    cfg = _cfg(rounds=2, lr=0.0, dp_sigma=0.5, clip_norm=1.0)
    sim = AsyncFedFogSimulator(
        cfg, AsyncConfig.fedasync(dispatch_interval_ms=1e9)
    )
    state = sim.init_state(0)

    def pop_and_handle(state, handler):
        ev, q = pop_event(state.queue)
        assert bool(ev.valid)
        state = state._replace(
            queue=q, t_ms=jnp.maximum(ev.time, state.t_ms)
        )
        return handler(state, ev)

    state = pop_and_handle(state, sim._on_dispatch)
    assert int(jnp.sum(state.busy)) >= 2, "need >=2 in-flight updates"
    p0 = state.params
    state = pop_and_handle(state, sim._on_complete)  # flush 1
    p1 = state.params
    state = pop_and_handle(state, sim._on_complete)  # flush 2
    p2 = state.params
    assert int(state.flush_idx) == 2
    noise1 = np.concatenate(
        [np.ravel(b - a) for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1))]
    )
    noise2 = np.concatenate(
        [np.ravel(b - a) for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    )
    assert np.abs(noise1).max() > 0 and np.abs(noise2).max() > 0
    assert not np.allclose(noise1, noise2), (
        "repeat flushes reused the dispatch's DP key"
    )


# --------------------------------------------------------------------- #
# staleness weighting (satellite: hypothesis property tests)
# --------------------------------------------------------------------- #
def _weights_case(rng, n=12):
    mask = jnp.asarray(rng.rand(n) < 0.7)
    sizes = jnp.asarray(rng.uniform(1.0, 500.0, n).astype(np.float32))
    stal = jnp.asarray(rng.randint(0, 10, n).astype(np.float32))
    return mask, sizes, stal


def test_zero_staleness_full_buffer_is_exactly_fedavg():
    """Required exact (bitwise) reduction: full-registry buffer, zero
    staleness → the async rule IS Eq. 6."""
    rng = np.random.RandomState(0)
    n = 10
    updates = [
        {"w": jnp.asarray(rng.randn(n, 6, 4).astype(np.float32)),
         "b": jnp.asarray(rng.randn(n, 4).astype(np.float32))}
    ]
    mask = jnp.ones((n,), bool)
    sizes = jnp.asarray(rng.uniform(1.0, 300.0, n).astype(np.float32))
    ref = fedavg_stacked(updates, mask, sizes)
    out = async_aggregate(updates, mask, sizes, jnp.zeros((n,)), 0.5)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # exponent 0 (discount disabled) is also exact, even with staleness
    out0 = async_aggregate(
        updates, mask, sizes, jnp.asarray(rng.randint(0, 9, n), jnp.float32), 0.0
    )
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fedasync_single_update_steps_by_discounted_delta():
    n = 6
    delta = [jnp.zeros((n, 3)).at[2].set(jnp.asarray([1.0, -2.0, 3.0]))]
    mask = jnp.zeros((n,), bool).at[2].set(True)
    sizes = jnp.full((n,), 100.0)
    for s, a in ((0.0, 0.5), (3.0, 0.5), (7.0, 1.0)):
        out = async_aggregate(
            delta, mask, sizes, jnp.full((n,), np.float32(s)), a
        )
        expect = float(stale_discount(jnp.asarray(s), a))
        np.testing.assert_allclose(
            np.asarray(out[0]),  # client axis reduced away
            expect * np.asarray([1.0, -2.0, 3.0]),
            rtol=1e-4,
        )


# Hypothesis property tests (dev dep — mirrors tests/test_core_properties.py)
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(
        s=st.floats(0.0, 1e4),
        ds=st.floats(0.0, 100.0),
        a=st.floats(0.0, 4.0),
    )
    @settings(max_examples=60, deadline=None)
    def test_hyp_discount_in_unit_interval_and_monotone(s, a, ds):
        d0 = float(stale_discount(jnp.asarray(s, jnp.float32), a))
        d1 = float(stale_discount(jnp.asarray(s + ds, jnp.float32), a))
        assert 0.0 < d0 <= 1.0
        assert d1 <= d0 + 1e-6, "discount must be non-increasing in staleness"
        assert float(stale_discount(jnp.zeros(()), a)) == 1.0

    @given(
        seed=st.integers(0, 2**16),
        a=st.floats(0.0, 3.0),
    )
    @settings(max_examples=25, deadline=None)
    def test_hyp_staleness_weights_normalized_and_bounded(seed, a):
        rng = np.random.RandomState(seed)
        mask, sizes, stal = _weights_case(rng)
        w, scale = staleness_weights(mask, sizes, stal, a)
        w = np.asarray(w)
        assert (w >= 0).all() and (w <= 1.0 + 1e-6).all()
        assert (w[~np.asarray(mask)] == 0).all()
        if np.asarray(mask).any():
            np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-4)
        assert 0.0 < float(scale) <= 1.0 + 1e-6

    @given(seed=st.integers(0, 2**16), a=st.floats(0.05, 3.0))
    @settings(max_examples=25, deadline=None)
    def test_hyp_weights_monotone_in_staleness(seed, a):
        """Raising one client's staleness cannot raise its weight."""
        rng = np.random.RandomState(seed)
        mask, sizes, stal = _weights_case(rng)
        if not np.asarray(mask).any():
            return
        i = int(np.flatnonzero(np.asarray(mask))[0])
        w0, _ = staleness_weights(mask, sizes, stal, a)
        w1, _ = staleness_weights(mask, sizes, stal.at[i].add(5.0), a)
        assert float(w1[i]) <= float(w0[i]) + 1e-6

    @given(
        seed=st.integers(0, 2**16),
        kill_kind=st.integers(0, 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_hyp_cancel_then_pop_never_yields_cancelled(seed, kill_kind):
        _check_cancel_then_pop(seed, kill_kind)


# --------------------------------------------------------------------- #
# churn & availability
# --------------------------------------------------------------------- #
def test_churn_zero_rates_is_identity():
    cfg = ChurnConfig()
    online = jnp.asarray([True, False, True, True])
    out = step_churn(cfg, online, jnp.asarray(1e5), jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(online))


def test_churn_rates_move_population():
    key = jax.random.PRNGKey(0)
    n = 512
    # heavy departure over a long dt: nearly everyone leaves
    out = step_churn(
        ChurnConfig(departure_rate=5.0), jnp.ones(n, bool), 10_000.0, key
    )
    assert int(jnp.sum(out)) < n // 4
    # heavy arrival: most of the offline mass comes back
    back = step_churn(
        ChurnConfig(arrival_rate=5.0), jnp.zeros(n, bool), 10_000.0, key
    )
    assert int(jnp.sum(back)) > 3 * n // 4


def test_available_mask_battery_death():
    cfg = ChurnConfig(death_batt=0.1)
    online = jnp.asarray([True, True, False])
    batt = jnp.asarray([0.5, 0.05, 0.9])
    np.testing.assert_array_equal(
        np.asarray(available_mask(cfg, online, batt)), [True, False, False]
    )


def test_engine_churn_drops_inflight_updates():
    h = AsyncFedFogSimulator(
        _cfg(rounds=10, num_clients=16, top_k=12),
        AsyncConfig.fedbuff(
            4, dispatch_interval_ms=300.0, straggler_sigma=0.4,
            churn=ChurnConfig(arrival_rate=0.2, departure_rate=0.8),
        ),
    ).run()
    assert h["lost_inflight"] > 0, "heavy churn should kill in-flight work"
    assert h["num_flushes"] > 0  # training still makes progress
