"""Fused delta-pipeline kernel vs per-stage references.

Contracts:
  (a) kernel (interpret) ≡ ``delta_pipeline_ref`` over the FULL gate
      matrix (DP × momentum × compression × clip × staleness) — BITWISE
      at disabled gates, tolerance-bounded at enabled ones;
  (b) the fused ``apply_compression`` path is bitwise-equal to the
      per-leaf reference loop;
  (c) the widened ``use_pallas_agg`` gates — sync simulator round with
      DP, async flush, pod-scale round with momentum/DP/compression —
      reproduce their reference paths to float tolerance.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.compression import apply_compression
from repro.fl.fuse import (
    fuse_clients,
    fuse_vector,
    fused_gaussian_noise,
    leaf_sizes,
    segment_ids,
    stacked_leaf_sizes,
)
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.kernels.delta_pipeline import (
    delta_pipeline_apply,
    delta_pipeline_ref,
    delta_sq_norms,
)

KEY = jax.random.PRNGKey(7)

# Two shape scales: "quick" exercises padding/odd segments, "full" is a
# simulator-sized buffer (the MLP the paper-scale engine trains).
SCALES = {
    "quick": dict(c=6, seg_sizes=(40, 8, 64, 16), block_d=64),
    "full": dict(c=32, seg_sizes=(784 * 16, 16, 16 * 62, 62), block_d=2048),
}


def _fixture(c, p):
    ks = jax.random.split(KEY, 6)
    return dict(
        upd=jax.random.normal(ks[0], (c, p)),
        base=jax.random.normal(ks[1], (p,)),
        mask=jax.random.bernoulli(ks[2], 0.7, (c,)),
        weights=jnp.abs(jax.random.normal(ks[3], (c,))) * 100,
        noise=0.1 * jax.random.normal(ks[4], (p,)),
        mu=jax.random.normal(ks[5], (p,)),
        staleness=jnp.arange(c, dtype=jnp.float32) % 4,
    )


GATES = list(
    itertools.product(
        [False, True],  # dp
        ["fedavg", "fedavgm", "fedadam"],  # momentum
        ["none", "int8", "topk"],  # compression
        [0.0, 1.5],  # clip
        [False, True],  # staleness
    )
)


@pytest.mark.parametrize("scale", list(SCALES))
@pytest.mark.parametrize("dp,opt,comp,clip,stale", GATES, ids=str)
def test_pipeline_matches_ref_gate_matrix(scale, dp, opt, comp, clip, stale):
    if scale == "full" and (dp, opt, comp, clip, stale) not in [
        # full scale: the all-off corner, the all-on corner, and one
        # mid-point per optimizer — the quick scale covers the matrix.
        (False, "fedavg", "none", 0.0, False),
        (True, "fedadam", "int8", 1.5, True),
        (True, "fedavgm", "topk", 0.0, True),
        (True, "fedavg", "topk", 1.5, False),
    ]:
        pytest.skip("full scale runs a gate subset")
    shp = SCALES[scale]
    c, seg_sizes, block_d = shp["c"], shp["seg_sizes"], shp["block_d"]
    fx = _fixture(c, sum(seg_sizes))
    kw = dict(
        lr=0.7,
        staleness=fx["staleness"] if stale else None,
        staleness_exponent=0.5,
        dp_noise=fx["noise"] if dp else None,
        momentum=fx["mu"] if opt != "fedavg" else None,
        clip_norm=clip,
        compression=comp,
        topk_fraction=0.1,
        seg_sizes=seg_sizes if comp != "none" else None,
        server_optimizer=opt,
        server_momentum=0.9,
    )
    out = delta_pipeline_apply(
        fx["upd"], fx["base"], fx["mask"], fx["weights"],
        block_d=block_d, **kw,
    )
    # jit the oracle too: eager-vs-jit FMA fusion is the only source of
    # 1-ulp noise in the disabled-gate comparison.
    ref = jax.jit(
        lambda u, b, m, w: delta_pipeline_ref(u, b, m, w, **kw)
    )(fx["upd"], fx["base"], fx["mask"], fx["weights"])
    outs = out if isinstance(out, tuple) else (out,)
    refs = ref if isinstance(ref, tuple) else (ref,)
    all_off = not dp and opt == "fedavg" and comp == "none" and clip == 0.0
    for o, r in zip(outs, refs):
        o, r = np.asarray(o), np.asarray(r)
        if all_off and not stale:
            np.testing.assert_array_equal(o, r)  # bitwise at disabled gates
        else:
            # fedadam divides by (|agg| + 1e-3): near-zero aggregates
            # amplify 1-ulp reduction-order noise, hence its wider tol.
            tol = 5e-3 if opt == "fedadam" else 1e-5
            np.testing.assert_allclose(o, r, atol=tol, rtol=1e-4)


def test_pipeline_zero_staleness_is_bitwise_plain():
    """disc(0)=1 and damping=1 exactly: a zero-staleness pipeline equals
    the staleness-free one bitwise (the async engine's sync-recovery
    contract, at kernel level)."""
    fx = _fixture(6, 128)
    a = delta_pipeline_apply(
        fx["upd"], fx["base"], fx["mask"], fx["weights"], lr=0.7,
        staleness=jnp.zeros((6,)), staleness_exponent=0.5, block_d=64,
    )
    b = delta_pipeline_apply(
        fx["upd"], fx["base"], fx["mask"], fx["weights"], lr=0.7,
        block_d=64,
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_delta_sq_norms_matches_jnp():
    fx = _fixture(8, 1000)
    out = delta_sq_norms(fx["upd"], block_d=256)
    ref = jnp.sum(jnp.square(fx["upd"]), axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_pipeline_all_masked_is_safe():
    fx = _fixture(4, 64)
    out = delta_pipeline_apply(
        fx["upd"], fx["base"], jnp.zeros((4,), bool), fx["weights"],
        block_d=64,
    )
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(fx["base"]), atol=1e-6
    )


# --------------------------------------------------------------------- #
# in-kernel robust aggregators (median / trimmed) vs core.aggregation
# --------------------------------------------------------------------- #
def _core_robust(upd, base, mask, lr, frac, agg):
    """Reference: core.aggregation robust aggregate + plain apply."""
    from repro.core.aggregation import median_aggregate, trimmed_mean_aggregate

    a = (
        median_aggregate(upd, mask)
        if agg == "median"
        else trimmed_mean_aggregate(upd, mask, frac)
    )
    return base + lr * a


_MASKS = {
    "random": None,  # the _fixture bernoulli mask
    "all": "all",
    "alternating": "alt",
}


@pytest.mark.parametrize("c", [5, 6])  # odd + even client counts
@pytest.mark.parametrize("mask_kind", list(_MASKS))
@pytest.mark.parametrize("agg,frac", [("median", 0.0), ("trimmed", 0.1),
                                      ("trimmed", 0.25)], ids=str)
def test_robust_kernel_bitwise_matches_core(agg, frac, mask_kind, c):
    """The in-kernel bitonic-selection median / trimmed mean is BITWISE
    equal to core.aggregation's jnp.sort-based references under masks
    (odd and even live counts)."""
    fx = _fixture(c, 192)
    mask = {
        "random": fx["mask"],
        "all": jnp.ones((c,), bool),
        "alternating": jnp.arange(c) % 2 == 0,
    }[mask_kind]
    out = delta_pipeline_apply(
        fx["upd"], fx["base"], mask, fx["weights"], 0.7,
        None, 0.0, None, None, frac,
        aggregator=agg, block_d=64,
    )
    # jit the oracle (same FMA-fusion rationale as the gate matrix).
    exp = jax.jit(_core_robust, static_argnames="agg")(
        fx["upd"], fx["base"], mask, 0.7, frac, agg=agg
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("agg", ["median", "trimmed"])
def test_robust_kernel_random_masks_deterministic(agg):
    """Seeded random-mask sweep (runs without hypothesis): varying client
    counts, live counts and trim fractions, bitwise vs core.aggregation."""
    rng = np.random.default_rng(42)
    for _ in range(8):
        c = int(rng.integers(2, 11))
        p = int(rng.integers(1, 200))
        frac = float(rng.uniform(0.0, 0.45))
        upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
        base = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
        mask = np.asarray(rng.random(c) < 0.6)
        mask[int(rng.integers(c))] = True  # ≥1 live client
        mask = jnp.asarray(mask)
        out = delta_pipeline_apply(
            upd, base, mask, jnp.ones((c,)), 1.0,
            None, 0.0, None, None, frac,
            aggregator=agg, block_d=64,
        )
        exp = jax.jit(_core_robust, static_argnames="agg")(
            upd, base, mask, 1.0, frac, agg=agg
        )
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(exp), err_msg=f"c={c} p={p} f={frac}"
        )


def test_robust_kernel_property_hypothesis():
    """Property form of the bitwise contract (hypothesis is a dev dep —
    skipped when absent; the deterministic sweep above always runs)."""
    pytest.importorskip(
        "hypothesis", reason="dev dependency; see requirements-dev.txt"
    )
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        c=st.integers(2, 12),
        p=st.integers(1, 96),
        frac=st.floats(0.0, 0.45),
        seed=st.integers(0, 2**31 - 1),
        agg=st.sampled_from(["median", "trimmed"]),
    )
    def prop(c, p, frac, seed, agg):
        rng = np.random.default_rng(seed)
        upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
        base = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
        mask = np.asarray(rng.random(c) < 0.6)
        mask[int(rng.integers(c))] = True
        mask = jnp.asarray(mask)
        out = delta_pipeline_apply(
            upd, base, mask, jnp.ones((c,)), 1.0,
            None, 0.0, None, None, frac,
            aggregator=agg, block_d=64,
        )
        exp = jax.jit(_core_robust, static_argnames="agg")(
            upd, base, mask, 1.0, frac, agg=agg
        )
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    prop()


def test_robust_with_dp_noise_matches_ref():
    """DP noise is added to the robust aggregate AFTER selection — the
    same caller-built stream as the fedavg path (gate flips must not
    change the noise; see test_fused_gaussian_noise_matches_reference)."""
    fx = _fixture(6, 192)
    out = delta_pipeline_apply(
        fx["upd"], fx["base"], fx["mask"], fx["weights"], 0.7,
        None, 0.0, fx["noise"], None, 0.1,
        aggregator="trimmed", block_d=64,
    )
    ref = jax.jit(
        lambda u, b, m, w, n: delta_pipeline_ref(
            u, b, m, w, 0.7, None, 0.0, n, None,
            aggregator="trimmed", trim_fraction=0.1,
        )
    )(fx["upd"], fx["base"], fx["mask"], fx["weights"], fx["noise"])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_robust_rejects_staleness():
    """median/trimmed are unweighted order statistics — staleness
    discounting does not compose with them; the kernel refuses loudly."""
    fx = _fixture(4, 64)
    with pytest.raises(ValueError, match="unweighted"):
        delta_pipeline_apply(
            fx["upd"], fx["base"], fx["mask"], fx["weights"], 1.0,
            fx["staleness"], 0.5, None, None, 0.1,
            aggregator="median", block_d=64,
        )


# --------------------------------------------------------------------- #
# fused buffer helpers + fused compression (satellite)
# --------------------------------------------------------------------- #
def _delta_tree(c=6):
    ks = jax.random.split(KEY, 3)
    return {
        "a": jax.random.normal(ks[0], (c, 13, 7)),
        "b": jax.random.normal(ks[1], (c, 5)),
        "c": jax.random.normal(ks[2], (c, 31)),
    }


def test_fuse_roundtrips():
    tree = _delta_tree()
    cat, unfuse = fuse_clients(tree)
    assert cat.shape == (6, 13 * 7 + 5 + 31)
    back = unfuse(cat)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(tree[k]))
    agg = unfuse(cat[0])
    for k in tree:
        np.testing.assert_array_equal(np.asarray(agg[k]), np.asarray(tree[k][0]))
    one = {k: v[0] for k, v in tree.items()}
    vec, unvec = fuse_vector(one)
    back = unvec(vec)
    for k in one:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(one[k]))
    assert stacked_leaf_sizes(tree) == leaf_sizes(one) == (13 * 7, 5, 31)
    seg = np.asarray(segment_ids(stacked_leaf_sizes(tree)))
    assert seg.shape == (13 * 7 + 5 + 31,)
    assert (np.bincount(seg) == [13 * 7, 5, 31]).all()


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_fused_compression_bitwise_matches_per_leaf(kind):
    tree = _delta_tree()
    fused = apply_compression(tree, kind, 0.1, fused=True)
    ref = apply_compression(tree, kind, 0.1, fused=False)
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(fused[k]), np.asarray(ref[k]), err_msg=f"{kind}/{k}"
        )


def test_fused_gaussian_noise_matches_reference_mechanism():
    """The fused (P,) noise vector reproduces gaussian_mechanism's
    per-leaf draws exactly — enabling the kernel must not change the DP
    noise stream."""
    from repro.core.privacy import DPConfig, gaussian_mechanism

    tree = {k: v[0] for k, v in _delta_tree().items()}
    key = jax.random.fold_in(KEY, 9)
    cfg = DPConfig(sigma=0.3, sensitivity=1.1)
    zeros = jax.tree.map(jnp.zeros_like, tree)
    ref = gaussian_mechanism(zeros, key, cfg)
    vec = fused_gaussian_noise(
        key, cfg.sigma * cfg.sensitivity, leaf_sizes(tree),
        [x.shape for x in jax.tree.leaves(tree)],
    )
    _, unvec = fuse_vector(zeros)
    back = unvec(vec)
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(back[k]), np.asarray(ref[k]), err_msg=k
        )


# --------------------------------------------------------------------- #
# widened use_pallas_agg gates, end to end
# --------------------------------------------------------------------- #
def _cfg(**kw) -> SimulatorConfig:
    base = dict(
        task="emnist", num_clients=8, rounds=3, top_k=4, hidden=(16,), seed=0
    )
    base.update(kw)
    return SimulatorConfig(**base)


@pytest.mark.parametrize(
    "extra",
    [
        {"dp_sigma": 0.3, "clip_norm": 1.0},
        {"compression": "int8"},
        {"compression": "topk", "dp_sigma": 0.2, "clip_norm": 0.5},
        {"aggregator": "median", "dp_sigma": 0.3, "clip_norm": 1.0},
        {"aggregator": "trimmed", "trim_fraction": 0.2, "compression": "int8"},
    ],
    ids=str,
)
def test_simulator_pallas_gate_widened(extra):
    """use_pallas_agg now engages WITH DP noise / compression configs in
    the paper-scale simulator and reproduces the reference engine."""
    cfg = _cfg(**extra)
    h_ref = FedFogSimulator(cfg).run_scanned()
    h_pal = FedFogSimulator(
        dataclasses.replace(cfg, use_pallas_agg=True)
    ).run_scanned()
    for name in h_ref:
        np.testing.assert_allclose(
            np.asarray(h_ref[name]), np.asarray(h_pal[name]),
            rtol=1e-5, atol=1e-5, err_msg=f"{extra}/{name}",
        )


@pytest.mark.parametrize(
    "extra",
    [{}, {"dp_sigma": 0.3, "clip_norm": 1.0}, {"aggregator": "median"}],
    ids=str,
)
def test_async_flush_pallas_matches_reference(extra):
    """The async flush path routes through the fused kernel under
    use_pallas_agg — staleness discounting, DP and apply included."""
    from repro.sim.events.engine import AsyncConfig, AsyncFedFogSimulator

    cfg = _cfg(rounds=4, **extra)
    acfg = AsyncConfig.fedbuff(
        2, dispatch_interval_ms=500.0, staleness_exponent=0.5,
        straggler_sigma=0.2,
    )
    h_ref = AsyncFedFogSimulator(cfg, acfg).run()
    h_pal = AsyncFedFogSimulator(
        dataclasses.replace(cfg, use_pallas_agg=True), acfg
    ).run()
    assert h_ref["num_flushes"] == h_pal["num_flushes"]
    assert h_ref["num_dispatches"] == h_pal["num_dispatches"]
    np.testing.assert_allclose(
        h_ref["accuracy"], h_pal["accuracy"], rtol=1e-5, atol=1e-5,
        err_msg=str(extra),
    )
    np.testing.assert_allclose(
        h_ref["mean_staleness"], h_pal["mean_staleness"], atol=1e-6
    )


@pytest.mark.parametrize(
    "kw",
    [
        dict(server_optimizer="fedavgm", dp_sigma=0.05, clip_norm=1.0),
        dict(server_optimizer="fedadam"),
        dict(server_optimizer="fedavg", compression="int8"),
    ],
    ids=str,
)
def test_pod_round_pallas_gate_widened(kw):
    """fl/round.py routes momentum / DP / compression configs through
    the fused pipeline kernel; params and server momentum match the
    reference round to bf16 tolerance (the kernel aggregates in f32
    where the bf16 reference aggregates in bf16 — it is the more
    precise of the two)."""
    from repro.fl import FLConfig, init_fl_state, make_round_fn
    from repro.models import Family, ModelConfig, build_model

    tiny = ModelConfig(
        name="tiny", family=Family.DENSE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=128, remat=False, loss_chunk=0,
    )
    model = build_model(tiny)
    fl_ref = FLConfig(num_clients=8, slots=4, **kw)
    fl_pal = dataclasses.replace(fl_ref, use_pallas_agg=True)

    ks = jax.random.split(KEY, 8)
    n = fl_ref.num_clients
    batch = {
        "tokens": jax.random.randint(ks[0], (16, 33), 0, 128),
        "slot_data_sizes": jnp.abs(jax.random.normal(ks[1], (4,))) * 100 + 10,
        "telemetry_cpu": jax.random.uniform(ks[2], (n,), minval=0.5, maxval=1.0),
        "telemetry_mem": jax.random.uniform(ks[3], (n,), minval=0.5, maxval=1.0),
        "telemetry_batt": jax.random.uniform(ks[4], (n,), minval=0.5, maxval=1.0),
        "telemetry_energy": jax.random.uniform(ks[5], (n,), minval=0.55, maxval=1.0),
        "hist": jnp.abs(jax.random.normal(ks[6], (n, fl_ref.hist_bins))) + 1.0,
    }
    s_ref, _ = jax.jit(make_round_fn(model, fl_ref))(
        init_fl_state(model, fl_ref, KEY), batch
    )
    s_pal, _ = jax.jit(make_round_fn(model, fl_pal))(
        init_fl_state(model, fl_pal, KEY), batch
    )
    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_pal.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-3, err_msg=str(kw),
        )
    assert (s_ref.server_mu is None) == (s_pal.server_mu is None)
    if s_ref.server_mu is not None:
        for a, b in zip(
            jax.tree.leaves(s_ref.server_mu), jax.tree.leaves(s_pal.server_mu)
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=5e-3, err_msg=f"mu {kw}"
            )
